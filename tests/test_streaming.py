"""Streaming out-of-core data plane: chunked sources, mergeable quantile
sketches, and incremental fit.

The load-bearing claims:
  * the quantile sketch is EXACT (bit-identical to np.quantile, hence to
    binning.quantile_boundaries) until it first compacts, and past that its
    tracked ``err`` is a proven additive rank-error bound — asserted
    property-style across chunk sizes, chunk orders, and merge orders;
  * streamed chunked ingest (CSV chunks, block chunks, products; shuffled
    rows; partial overlap) builds a partition BIT-IDENTICAL to the
    in-memory ``partition_from_blocks`` on both tasks and both substrates;
  * ``ingest_append`` + refit equals a from-scratch ingest+fit of the
    concatenated data, and ``fit_resumable`` extends a checkpointed forest
    bit-identically to a larger from-scratch fit (per-tree counter-based
    randomness), restarting cleanly when the fingerprint detects new data;
  * DataProduct schemas are validated loudly per chunk and product versions
    must advance across appends.
"""
import numpy as np
import pytest

from repro.core import ForestParams, PartyBlock, partition_from_blocks
from repro.core.binning import quantile_boundaries
from repro.data import make_classification, make_party_views, make_regression
from repro.federation import Federation
from repro.federation.transport import RetryPolicy
from repro.streaming import (ArraySource, ChunkedCSVSource, DataProduct,
                             FeatureSketches, ProductSchema, QuantileSketch)

M = 3


def _parts_equal(a, b):
    np.testing.assert_array_equal(a.xb, b.xb)
    np.testing.assert_array_equal(a.feat_gid, b.feat_gid)
    np.testing.assert_array_equal(a.boundaries, b.boundaries)
    assert a.n_features == b.n_features
    assert a.party_names == b.party_names


def _trees_equal(a, b):
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------------------ sketches
def test_sketch_exact_regime_bit_identical_to_dense_binning():
    """Under capacity the sketch never compacts: its edges are literally
    np.quantile at the grid levels — bit-equal to quantile_boundaries."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 4)) * [1.0, 10.0, 0.1, 100.0]
    fs = FeatureSketches(4, capacity=512)
    for lo in range(0, 500, 37):                  # ragged chunks
        fs.update(x[lo:lo + 37])
    assert fs.exact and fs.err == 0
    np.testing.assert_array_equal(fs.edges(16), quantile_boundaries(x, 16))
    # chunk order cannot matter in the exact regime (buffer is a multiset)
    fs2 = FeatureSketches(4, capacity=512)
    for lo in reversed(range(0, 500, 23)):
        fs2.update(x[lo:lo + 23])
    np.testing.assert_array_equal(fs.edges(16), fs2.edges(16))


def test_sketch_rejects_non_finite():
    with pytest.raises(ValueError, match="non-finite"):
        QuantileSketch(capacity=8).update([1.0, np.nan])


def _rank_within(data_sorted, value, target_rank, err):
    """True rank of ``value`` (as an interval, for ties/interpolation) is
    within ``err`` (+1 for interpolation between adjacent ranks) of
    ``target_rank``."""
    lo = np.searchsorted(data_sorted, value, side="left")
    hi = np.searchsorted(data_sorted, value, side="right")
    return lo - (err + 1) <= target_rank <= hi + (err + 1)


@pytest.mark.parametrize("chunk,seed", [(64, 1), (173, 2), (512, 3)])
def test_sketch_error_bound_property(chunk, seed):
    """Property: however the stream is chunked and merged, every bin edge's
    true rank is within the sketch's *tracked* ``err`` of the grid rank,
    and ``err`` itself stays near the classic log2(n/k)/k bound."""
    rng = np.random.default_rng(seed)
    n, k = 6000, 64
    data = np.concatenate([rng.normal(size=n // 2),
                           rng.exponential(size=n // 2) * 40.0])
    rng.shuffle(data)
    data_sorted = np.sort(data)

    # one sketch fed sequentially, and a merge tree over per-chunk sketches
    seq = QuantileSketch(capacity=k)
    parts = []
    for lo in range(0, n, chunk):
        seq.update(data[lo:lo + chunk])
        parts.append(QuantileSketch(capacity=k).update(data[lo:lo + chunk]))
    merged = parts[0]
    for p in parts[1:]:
        merged = merged.merge(p)
    # merge-order invariance of the guarantee: reversed merge order too
    rev = parts[-1]
    for p in reversed(parts[:-1]):
        rev = p.merge(rev)

    qs = np.linspace(0.0, 1.0, 17)[1:-1]
    for sk in (seq, merged, rev):
        assert sk.n == n
        assert 0 < sk.err <= 4 * (np.log2(n / k) + 2) * k  # tracked, sane
        for q, v in zip(qs, sk.quantiles(qs)):
            assert _rank_within(data_sorted, v, q * (n - 1), sk.err), \
                f"edge at q={q} outside tracked rank error {sk.err}"


def test_sketch_merge_exact_regime_is_order_invariant():
    rng = np.random.default_rng(7)
    chunks = [rng.normal(size=s) for s in (40, 11, 96, 3)]
    sks = [QuantileSketch(capacity=256).update(c) for c in chunks]
    a = sks[0].merge(sks[1]).merge(sks[2]).merge(sks[3])
    b = sks[3].merge(sks[2]).merge(sks[1]).merge(sks[0])
    assert a.exact and b.exact
    qs = np.linspace(0, 1, 9)[1:-1]
    np.testing.assert_array_equal(a.quantiles(qs), b.quantiles(qs))
    np.testing.assert_array_equal(
        a.quantiles(qs), np.quantile(np.concatenate(chunks), qs))


# ------------------------------------------------- streamed ingest (local)
@pytest.mark.parametrize("task", ["classification", "regression"])
def test_streamed_ingest_bit_identical_to_in_memory(task, tmp_path):
    """The losslessness oracle: chunked CSV + block sources, shuffled rows,
    partial overlap — the streamed build equals partition_from_blocks and
    the downstream fit is bit-identical."""
    if task == "classification":
        x, y = make_classification(260, 9, 3, seed=5)
    else:
        x, y = make_regression(260, 9, seed=5)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.8, seed=5)
    ref_part, ref_y, ref_ids = partition_from_blocks(blocks, n_bins=16)

    sources = [ChunkedCSVSource(b.to_csv(str(tmp_path / f"{b.name}.csv")),
                                name=b.name)
               for b in blocks[:-1]] + [ArraySource(blocks[-1])]
    fed = Federation(parties=M, n_bins=16)
    part = fed.ingest(sources, chunk_rows=29)
    _parts_equal(part, ref_part)
    np.testing.assert_array_equal(fed._y, ref_y)
    np.testing.assert_array_equal(fed.aligned_ids_, ref_ids)

    p = ForestParams(task=task, n_estimators=2, max_depth=3, n_bins=16,
                     n_classes=3, seed=3)
    ref_fed = Federation(parties=M, n_bins=16)
    ref_fed.ingest(blocks)
    _trees_equal(fed.fit(p).trees_, ref_fed.fit(p).trees_)


def test_streamed_ingest_chunk_size_invariance(tmp_path):
    """Chunk size is an execution knob, not a semantic one."""
    x, y = make_classification(150, 6, 2, seed=11)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.9, seed=11)
    ref, ref_y, _ = partition_from_blocks(blocks, n_bins=8)
    for rows in (1, 7, 64, 4096):
        fed = Federation(parties=M, n_bins=8)
        part = fed.ingest([ArraySource(b) for b in blocks], chunk_rows=rows)
        _parts_equal(part, ref)
        np.testing.assert_array_equal(fed._y, ref_y)


def test_streamed_ingest_knob_errors():
    x, y = make_classification(60, 6, 2, seed=0)
    blocks, _, _ = make_party_views(x, y, M, seed=0)
    fed = Federation(parties=M, n_bins=8)
    with pytest.raises(ValueError, match="chunked sources"):
        fed.ingest(blocks, chunk_rows=16)      # block path: knob must bark
    with pytest.raises(ValueError, match="y/contiguous/seed"):
        fed.ingest([ArraySource(b) for b in blocks], y=y)
    with pytest.raises(ValueError, match="declares 3"):
        fed.ingest([ArraySource(blocks[0])])
    with pytest.raises(ValueError, match="ingest_append extends"):
        fed.ingest_append([ArraySource(blocks[0])])


# ----------------------------------------------------------- incremental fit
def test_ingest_append_and_refit_match_from_scratch():
    """Appended rows re-assemble to exactly the from-scratch union build;
    a fit after the append is bit-identical to fitting the union."""
    x, y = make_classification(200, 6, 2, seed=21)
    blocks, _, _ = make_party_views(x, y, M, overlap=1.0, seed=21)
    x2, y2 = make_classification(80, 6, 2, seed=22)
    blocks2, _, _ = make_party_views(x2, y2, M, overlap=1.0, seed=21)
    blocks2 = [PartyBlock(name=b.name, x=b.x,
                          ids=np.array([f"new{i}" for i in range(len(b.ids))]),
                          y=b.y, feature_ids=b.feature_ids)
               for b in blocks2]
    union = [PartyBlock(name=a.name, x=np.concatenate([a.x, b.x]),
                        ids=np.concatenate([a.ids, b.ids]),
                        y=None if a.y is None else np.concatenate([a.y, b.y]),
                        feature_ids=a.feature_ids)
             for a, b in zip(blocks, blocks2)]
    ref_part, ref_y, ref_ids = partition_from_blocks(union, n_bins=16)

    fed = Federation(parties=M, n_bins=16)
    fed.ingest([ArraySource(b) for b in blocks], chunk_rows=33)
    part = fed.ingest_append([ArraySource(b) for b in blocks2])
    _parts_equal(part, ref_part)
    np.testing.assert_array_equal(fed._y, ref_y)
    np.testing.assert_array_equal(fed.aligned_ids_, ref_ids)

    p = ForestParams(n_estimators=3, max_depth=3, n_bins=16, seed=9)
    ref_fed = Federation(parties=M, n_bins=16)
    ref_fed.ingest(union)
    _trees_equal(fed.fit(p).trees_, ref_fed.fit(p).trees_)


def test_fit_resumable_extends_bit_identically(tmp_path):
    """Counter-based per-tree randomness: growing n_estimators on an
    existing checkpoint builds only the new trees, yet the result equals a
    from-scratch fit at the larger count."""
    x, y = make_classification(150, 6, 2, seed=2)
    fed = Federation(parties=M, n_bins=8)
    fed.ingest(x, y)
    small = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=4)
    big = ForestParams(n_estimators=5, max_depth=3, n_bins=8, seed=4)
    ck = str(tmp_path / "ck")
    m_small = fed.fit_resumable(small, ck, trees_per_chunk=2)
    m_big = fed.fit_resumable(big, ck, trees_per_chunk=2, model=m_small)
    assert m_big is m_small                       # continued in place
    ref = fed.fit(big)
    _trees_equal(m_big.trees_, ref.trees_)
    # prefix stability: the first 2 trees are the small fit's trees
    import jax
    _trees_equal(jax.tree.map(lambda a: a[:, :2], ref.trees_),
                 fed.fit(small).trees_)


def test_fit_resumable_fingerprint_restarts_on_new_data(tmp_path):
    """After ingest_append the checkpoint no longer matches the training
    set: the stale chunks must be discarded, not grafted onto new data."""
    x, y = make_classification(160, 6, 2, seed=31)
    blocks, _, _ = make_party_views(x, y, M, overlap=1.0, seed=31)
    fed = Federation(parties=M, n_bins=8)
    fed.ingest([ArraySource(b) for b in blocks])
    p = ForestParams(n_estimators=3, max_depth=3, n_bins=8, seed=6)
    ck = str(tmp_path / "ck")
    fed.fit_resumable(p, ck, trees_per_chunk=1)

    extra = [PartyBlock(name=b.name, x=b.x[:30] + 0.5,
                        ids=np.array([f"e{i}" for i in range(30)]),
                        y=None if b.y is None else b.y[:30],
                        feature_ids=b.feature_ids)
             for b in blocks]
    fed.ingest_append([ArraySource(b) for b in extra])
    resumed = fed.fit_resumable(p, ck, trees_per_chunk=1)
    ref = fed.fit(p)                              # from scratch on the union
    _trees_equal(resumed.trees_, ref.trees_)


# -------------------------------------------------------------- data products
def test_data_product_schema_validated_loudly():
    rng = np.random.default_rng(0)
    b = PartyBlock("bank", rng.normal(size=(20, 3)),
                   ids=[f"u{i}" for i in range(20)])
    good = DataProduct("bank", ArraySource(b), ProductSchema.of(b))
    assert sum(c.n_samples for c in good.iter_chunks(7)) == 20
    for schema, msg in [
            (ProductSchema(n_features=4), "declared 4 features"),
            (ProductSchema(n_features=3, feature_dtype="float32"),
             "declared feature dtype"),
            (ProductSchema(n_features=3, id_kind="int"), "ID contract"),
            (ProductSchema(n_features=3, has_labels=True), "has_labels"),
            (ProductSchema(n_features=3, feature_ids=(0, 1, 2)),
             "feature_ids")]:
        with pytest.raises(ValueError, match=msg):
            list(DataProduct("bank", ArraySource(b), schema).iter_chunks(7))
    with pytest.raises(ValueError, match="carry the product name"):
        list(DataProduct("ecom", ArraySource(b),
                         ProductSchema.of(b)).iter_chunks(7))


def test_data_product_versions_must_advance():
    x, y = make_classification(90, 6, 2, seed=41)
    blocks, _, _ = make_party_views(x, y, M, overlap=1.0, seed=41)
    fed = Federation(parties=M, n_bins=8)
    fed.ingest([DataProduct(b.name, ArraySource(b), ProductSchema.of(b),
                            version=1) for b in blocks])
    stale = DataProduct(blocks[0].name, ArraySource(PartyBlock(
        name=blocks[0].name, x=blocks[0].x[:5],
        ids=np.array([f"v{i}" for i in range(5)]),
        y=None if blocks[0].y is None else blocks[0].y[:5],
        feature_ids=blocks[0].feature_ids)),
        ProductSchema.of(blocks[0]), version=1)
    with pytest.raises(ValueError, match="does not advance"):
        fed.ingest_append([stale])
    with pytest.raises(ValueError, match="cannot add new ones"):
        fed.ingest_append([ArraySource(PartyBlock(
            "stranger", np.zeros((2, 1)), ids=["a", "b"]))])


# -------------------------------------------------------------- distributed
@pytest.fixture(scope="module")
def dist_fed():
    fed = Federation(parties=M, substrate="distributed", n_bins=8,
                     round_timeout=60.0,
                     retry=RetryPolicy(attempts=2, base=0.05, seed=0))
    yield fed
    fed.close()


def test_distributed_streamed_ingest_and_append_bit_identity(dist_fed,
                                                             tmp_path):
    """Party workers scan + bin their own chunks process-side; the
    partition the coordinator assembles — and the append re-assembly —
    equal the in-memory build exactly."""
    x, y = make_classification(140, 6, 2, seed=51)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.85, seed=51)
    ref, ref_y, _ = partition_from_blocks(blocks, n_bins=8)
    sources = [ChunkedCSVSource(b.to_csv(str(tmp_path / f"{b.name}.csv")),
                                name=b.name)
               for b in blocks]
    part = dist_fed.ingest(sources, chunk_rows=19)
    _parts_equal(part, ref)
    np.testing.assert_array_equal(dist_fed._y, ref_y)

    extra = [PartyBlock(name=b.name, x=b.x[:25] * 2.0,
                        ids=np.array([f"x{i}" for i in range(25)]),
                        y=None if b.y is None else b.y[:25],
                        feature_ids=b.feature_ids)
             for b in blocks]
    union = [PartyBlock(name=a.name, x=np.concatenate([a.x, b.x]),
                        ids=np.concatenate([a.ids, b.ids]),
                        y=None if a.y is None else np.concatenate([a.y, b.y]),
                        feature_ids=a.feature_ids)
             for a, b in zip(blocks, extra)]
    ref2, ref2_y, _ = partition_from_blocks(union, n_bins=8)
    part2 = dist_fed.ingest_append([DataProduct(b.name, ArraySource(b),
                                                ProductSchema.of(b),
                                                version=2) for b in extra])
    _parts_equal(part2, ref2)
    np.testing.assert_array_equal(dist_fed._y, ref2_y)

    p = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=1)
    sim = Federation(parties=M, n_bins=8)
    sim.ingest(union)
    _trees_equal(dist_fed.fit(p).trees_, sim.fit(p).trees_)


# ------------------------------------------------------------------- parquet
def _block_to_parquet(b, path):
    """Write a PartyBlock as parquet with to_csv's column semantics
    (gf<N> feature headers, id first, label last)."""
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    names = tuple(f"gf{j}" for j in b.feature_ids) if b.feature_ids is not None \
        else (b.feature_names or tuple(f"f{j}" for j in range(b.n_features)))
    cols = {"id": pa.array(np.asarray(b.ids))}
    for j, name in enumerate(names):
        cols[name] = pa.array(np.asarray(b.x[:, j], dtype=np.float64))
    if b.y is not None:
        cols["label"] = pa.array(np.asarray(b.y))
    pq.write_table(pa.table(cols), path)
    return path


def test_parquet_source_chunks_match_csv_source(tmp_path):
    pytest.importorskip("pyarrow")
    from repro.streaming import ChunkedParquetSource
    x, y = make_classification(110, 6, 2, seed=23)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.9, seed=23)
    b = blocks[0]
    csv_src = ChunkedCSVSource(b.to_csv(str(tmp_path / "p.csv")), name="p")
    pq_src = ChunkedParquetSource(
        _block_to_parquet(b, str(tmp_path / "p.parquet")), name="p")
    for rows in (7, 1000):
        cc = list(csv_src.iter_chunks(rows))
        pc = list(pq_src.iter_chunks(rows))
        assert len(cc) == len(pc)
        for a, q in zip(cc, pc):
            np.testing.assert_array_equal(a.x, q.x)
            np.testing.assert_array_equal(
                np.asarray(a.ids, dtype=str), np.asarray(q.ids, dtype=str))
            if a.y is None:
                assert q.y is None
            else:
                np.testing.assert_array_equal(a.y, q.y)
            np.testing.assert_array_equal(a.feature_ids, q.feature_ids)
            assert a.feature_names == q.feature_names
    with pytest.raises(ValueError, match=">= 1"):
        next(pq_src.iter_chunks(0))


def test_parquet_streamed_ingest_bit_identical_to_in_memory(tmp_path):
    pytest.importorskip("pyarrow")
    from repro.streaming import ChunkedParquetSource
    x, y = make_classification(150, 9, 3, seed=29)
    blocks, _, _ = make_party_views(x, y, M, overlap=0.8, seed=29)
    ref, ref_y, _ = partition_from_blocks(blocks, n_bins=8)
    sources = [ChunkedParquetSource(
        _block_to_parquet(b, str(tmp_path / f"{b.name}.parquet")),
        name=b.name) for b in blocks]
    fed = Federation(parties=M, n_bins=8)
    part = fed.ingest(sources, chunk_rows=31)
    _parts_equal(part, ref)
    np.testing.assert_array_equal(fed._y, ref_y)


def test_parquet_empty_file_yields_one_empty_chunk(tmp_path):
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    from repro.streaming import ChunkedParquetSource
    t = pa.table({"id": pa.array([], type=pa.int64()),
                  "gf0": pa.array([], type=pa.float64()),
                  "gf1": pa.array([], type=pa.float64())})
    pq.write_table(t, str(tmp_path / "empty.parquet"))
    chunks = list(ChunkedParquetSource(
        str(tmp_path / "empty.parquet")).iter_chunks(16))
    assert len(chunks) == 1
    assert chunks[0].x.shape == (0, 2) and chunks[0].ids.shape == (0,)
    np.testing.assert_array_equal(chunks[0].feature_ids, [0, 1])
