"""The paper's central claim, in its strongest form: FF(M parties) produces
BIT-IDENTICAL trees and predictions to the centralized forest (M=1), for both
tasks and any M — not just statistically comparable accuracy."""
import jax
import numpy as np
import pytest

from repro.core import ForestParams, fit_federated_forest
from repro.data import make_classification, make_regression
from repro.data.metrics import accuracy, rmse


def _cls_data(seed=0, n=500, f=24, c=2):
    x, y = make_classification(n, f, c, seed=seed)
    cut = int(0.75 * n)
    return x[:cut], y[:cut], x[cut:], y[cut:]


@pytest.mark.parametrize("m", [2, 3, 5, 8])
def test_lossless_classification(m):
    xtr, ytr, xte, yte = _cls_data()
    p = ForestParams(n_estimators=5, max_depth=5, n_bins=16, seed=7)
    central = fit_federated_forest(xtr, ytr, 1, p)
    fed = fit_federated_forest(xtr, ytr, m, p)
    np.testing.assert_array_equal(central.predict(xte), fed.predict(xte))
    # and the model itself is useful, not degenerate
    assert accuracy(yte, fed.predict(xte)) > 0.7


@pytest.mark.parametrize("m", [2, 4])
def test_lossless_regression(m):
    x, y = make_regression(500, 18, seed=3)
    p = ForestParams(task="regression", n_estimators=4, max_depth=5,
                     n_bins=16, seed=1)
    central = fit_federated_forest(x[:400], y[:400], 1, p)
    fed = fit_federated_forest(x[:400], y[:400], m, p)
    a, b = central.predict(x[400:]), fed.predict(x[400:])
    np.testing.assert_allclose(a, b, rtol=0, atol=1e-5)
    assert rmse(y[400:], b) < rmse(y[400:], np.full(100, y[:400].mean()))


def test_lossless_multiclass():
    xtr, ytr, xte, yte = _cls_data(seed=5, c=3)
    p = ForestParams(n_classes=3, n_estimators=4, max_depth=4, n_bins=16, seed=2)
    np.testing.assert_array_equal(
        fit_federated_forest(xtr, ytr, 1, p).predict(xte),
        fit_federated_forest(xtr, ytr, 3, p).predict(xte))


def test_master_tree_identical_across_party_counts():
    """The complete tree T (master view) is the same regardless of M."""
    xtr, ytr, _, _ = _cls_data(seed=9)
    p = ForestParams(n_estimators=3, max_depth=4, n_bins=8, seed=4)
    t1 = fit_federated_forest(xtr, ytr, 1, p).master_tree_view()
    t4 = fit_federated_forest(xtr, ytr, 4, p).master_tree_view()
    np.testing.assert_array_equal(t1["split_gid"], t4["split_gid"])
    np.testing.assert_array_equal(t1["is_leaf"], t4["is_leaf"])
    np.testing.assert_allclose(t1["leaf_stats"], t4["leaf_stats"], atol=1e-5)


def test_label_encryption_invariance():
    """Training on permuted class ids / affine-masked targets decodes exactly
    (crypto.py invariants the privacy layer relies on)."""
    xtr, ytr, xte, _ = _cls_data(seed=11)
    p = ForestParams(n_estimators=3, max_depth=4, n_bins=16, seed=6)
    enc = fit_federated_forest(xtr, ytr, 2, p, encrypt_labels=True)
    plain = fit_federated_forest(xtr, ytr, 2, p, encrypt_labels=False)
    np.testing.assert_array_equal(enc.predict(xte), plain.predict(xte))

    # Regression masking is only gain-preserving up to float32 cancellation
    # (the paper concedes the same trade-off, §4.3): assert statistical
    # parity, not bit equality.
    x, y = make_regression(400, 12, seed=8)
    pr = ForestParams(task="regression", n_estimators=3, max_depth=4,
                      n_bins=16, seed=6)
    enc = fit_federated_forest(x[:300], y[:300], 2, pr, mask_regression=True)
    plain = fit_federated_forest(x[:300], y[:300], 2, pr, mask_regression=False)
    r_enc = rmse(y[300:], enc.predict(x[300:]))
    r_plain = rmse(y[300:], plain.predict(x[300:]))
    assert abs(r_enc - r_plain) / r_plain < 0.15


def test_oneround_equals_classical_prediction():
    """Proposition 1 end-to-end: the intersection method == routed prediction."""
    xtr, ytr, xte, _ = _cls_data(seed=13)
    p = ForestParams(n_estimators=6, max_depth=6, n_bins=16, seed=3)
    ff = fit_federated_forest(xtr, ytr, 5, p)
    np.testing.assert_array_equal(ff.predict(xte), ff.predict_classical(xte))


def test_noncontiguous_partition_still_accurate():
    """Permuted (realistic) feature assignment: equality holds up to gain
    ties, so we assert prediction agreement rate ~1 and accuracy parity."""
    xtr, ytr, xte, yte = _cls_data(seed=17, n=600)
    p = ForestParams(n_estimators=5, max_depth=5, n_bins=16, seed=5)
    central = fit_federated_forest(xtr, ytr, 1, p)
    fed = fit_federated_forest(xtr, ytr, 4, p, contiguous=False)
    agree = np.mean(central.predict(xte) == fed.predict(xte))
    assert agree > 0.95
    assert abs(accuracy(yte, central.predict(xte))
               - accuracy(yte, fed.predict(xte))) < 0.05


def test_distributed_storage_privacy_invariant():
    """No party stores split details for nodes it does not own, and the union
    of partial trees covers every split (T = T_1 ∪ ... ∪ T_M)."""
    xtr, ytr, _, _ = _cls_data(seed=19)
    p = ForestParams(n_estimators=3, max_depth=5, n_bins=16, seed=9)
    ff = fit_federated_forest(xtr, ytr, 4, p)
    trees = jax.tree.map(np.asarray, ff.trees_)
    owner = trees.owner[0]          # master view, (T, nn)
    for i in range(4):
        mine = trees.has_split[i]
        # storing a split  <=>  owning the node
        np.testing.assert_array_equal(mine, owner == i)
        # foreign/leaf nodes carry no feature/threshold
        assert (trees.split_floc[i][~mine] == -1).all()
        assert (trees.split_bin[i][~mine] == -1).all()
    # union covers every split node exactly once
    n_owned = sum((trees.has_split[i]).sum() for i in range(4))
    assert n_owned == (owner >= 0).sum()
