"""End-to-end behaviour tests for the paper's system.

The full story in one test: two organizations align IDs, train a federated
forest where no raw feature crosses the boundary, predict with one
collective, and the result is bit-identical to centralized training —
the paper's Given/Learn/Constraint statement (§3.2) executed end to end.
"""
import numpy as np

from repro.core import (ForestParams, FederatedForest, crypto,
                        fit_federated_forest, party)
from repro.data import make_classification
from repro.data.metrics import accuracy
from repro.data.tabular import train_test_split


def test_end_to_end_cross_silo_scenario():
    # -- two data islands, shared sample space (paper §3.1) ---------------
    x, y = make_classification(1200, 40, 2, n_informative=10, seed=42)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=0)

    # -- private ID alignment (paper §4.3) ---------------------------------
    ids = np.arange(len(xtr))
    ia, ib = crypto.align_ids(crypto.hash_ids(ids), crypto.hash_ids(ids))
    assert len(ia) == len(xtr)

    # -- Learn: complete tree on master, partial trees on clients ----------
    p = ForestParams(n_estimators=8, max_depth=6, n_bins=32, seed=1)
    partition = party.make_vertical_partition(xtr, 2, p.n_bins)
    ff = FederatedForest(p).fit(partition, ytr)

    view = ff.master_tree_view()
    assert (view["owner"] >= 0).any()            # master knows owners
    trees = ff.trees_
    import jax
    t = jax.tree.map(np.asarray, trees)
    for i in range(2):                           # clients store only their own
        assert (t.split_floc[i][~t.has_split[i]] == -1).all()

    # -- Predict: one collective; useful model ------------------------------
    pred = ff.predict(xte)
    acc = accuracy(yte, pred)
    assert acc > 0.8, acc

    # -- Constraint (§3.2): performance == non-federated --------------------
    central = fit_federated_forest(xtr, ytr, 1, p)
    np.testing.assert_array_equal(central.predict(xte), pred)
