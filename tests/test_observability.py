"""Observability subsystem: tracing, telemetry registry, breaker
transitions, export/report — and the zero-cost-when-disabled contract.

The load-bearing guarantees under test:

  * **Propagation** — one distributed 3-party fit yields ONE connected
    trace: every worker span carries the coordinator's trace id and hangs
    off a coordinator parent span; retry backoff spans carry the
    reproducible jittered schedule.
  * **Bit-identity** — enabling tracing changes no protocol or serving
    output (and the disabled path never adds the ``_trace`` key to wire
    messages at all).
  * **Breaker observer seam** — open -> half_open -> closed transitions
    are recorded in order, both with an injected clock and under the
    workers' deterministic chaos hook.
  * **Metadata-only payloads** — span attrs reject array-shaped values at
    runtime (the static egress linter proves the same at the call sites).
"""
import json
import os

import numpy as np
import pytest

from repro.core import ForestParams
from repro.data import make_classification
from repro.federation import Federation, distributed
from repro.federation.distributed import DistributedSubstrate
from repro.federation.transport import (CircuitBreaker, CircuitOpenError,
                                        PartyTimeout, RetryPolicy)
from repro.observability import (REGISTRY, TRACER, Registry, Tracer,
                                 chrome_trace, critical_path, export_jsonl,
                                 format_report, read_jsonl)
from repro.serving import ServeConfig

M = 3


@pytest.fixture()
def tracer():
    """A private, enabled Tracer — global TRACER state stays untouched."""
    t = Tracer()
    t.enable()
    yield t
    t.disable()
    t.reset()


@pytest.fixture()
def armed_tracer():
    """The GLOBAL tracer, enabled (with the env the workers inherit) and
    guaranteed clean again afterwards — for end-to-end propagation tests."""
    os.environ["REPRO_TRACE"] = "1"
    TRACER.enable()
    TRACER.reset()
    yield TRACER
    TRACER.disable()
    TRACER.reset()
    os.environ.pop("REPRO_TRACE", None)


# ------------------------------------------------------------------- tracer
def test_disabled_tracer_is_noop_and_allocation_free():
    t = Tracer()
    s1 = t.span("a", category="host")
    s2 = t.span("b", category="comm", level=3)
    assert s1 is s2                      # shared no-op singleton
    with s1:
        assert t.current_context() is None
    assert t.begin("c") is None
    t.finish(None)                       # no-op, no error
    t.event("d")
    assert t.spans() == []


def test_span_nesting_parent_chain_and_single_trace(tracer):
    with tracer.span("root", category="host"):
        with tracer.span("mid", category="comm", level=1):
            with tracer.span("leaf", category="compute"):
                pass
        tracer.event("blip", category="host")
    spans = {s["name"]: s for s in tracer.spans()}
    assert len(spans) == 4
    assert spans["root"]["parent"] is None
    assert spans["mid"]["parent"] == spans["root"]["sid"]
    assert spans["leaf"]["parent"] == spans["mid"]["sid"]
    assert spans["blip"]["parent"] == spans["root"]["sid"]
    assert len({s["tid"] for s in spans.values()}) == 1
    assert spans["mid"]["attrs"]["level"] == 1
    assert spans["leaf"]["dur"] <= spans["mid"]["dur"] * 1.5 + 1e-3


def test_attach_adopts_remote_parent_even_when_env_disabled():
    """A worker with tracing off locally still records under a propagated
    remote context — that's how coordinator-armed tracing reaches workers."""
    t = Tracer()
    assert not t.enabled
    ctx = {"tid": "t9", "sid": "coord/9"}
    with t.attach(ctx):
        with t.span("remote_child", category="compute"):
            pass
    with t.attach(None):                 # no context: stays off
        with t.span("dropped"):
            pass
    spans = t.spans()
    assert [s["name"] for s in spans] == ["remote_child"]
    assert spans[0]["tid"] == "t9"
    assert spans[0]["parent"] == "coord/9"


def test_span_attrs_reject_payload_shaped_values(tracer):
    with pytest.raises(TypeError, match="metadata"):
        with tracer.span("bad", rows=np.arange(5)):
            pass
    with pytest.raises(TypeError, match="metadata"):
        tracer.event("bad2", ids={"a": 1})
    with pytest.raises(TypeError, match="metadata"):
        tracer.event("bad3", big=tuple(range(100)))   # past the tuple bound
    tracer.event("ok", shape=(3, 4), note="fine")     # short tuples pass


def test_manual_begin_finish_tolerates_out_of_order(tracer):
    a = tracer.begin("wave0", category="compute")
    b = tracer.begin("wave1", category="compute")
    tracer.finish(a)                     # FIFO finish under a LIFO stack
    tracer.finish(b)
    names = [s["name"] for s in tracer.spans()]
    assert sorted(names) == ["wave0", "wave1"]


# ----------------------------------------------------------------- registry
def test_registry_counter_gauge_histogram_and_names():
    r = Registry()
    r.counter("a.hits").inc()
    r.counter("a.hits").inc(4)
    r.gauge("a.depth").set(7)
    h = r.histogram("a.lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert r.counter("a.hits").value == 5
    assert r.gauge("a.depth").value == 7
    assert h.count == 4 and h.total == 10.0 and h.max == 4.0
    assert h.quantile(0.5) in (2.0, 3.0)
    assert set(r.names()) == {"a.hits", "a.depth", "a.lat"}
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("a.hits")                # kind collision is loud


def test_registry_snapshot_merge_rollup_prefix():
    worker, coord = Registry(), Registry()
    worker.counter("rows").inc(10)
    worker.histogram("lat").observe(0.5)
    worker.histogram("lat").observe(1.5)
    coord.merge(worker.snapshot(), prefix="party2.")
    coord.merge(worker.snapshot(), prefix="party1.")
    assert coord.counter("party2.rows").value == 10
    assert coord.counter("party1.rows").value == 10
    assert coord.histogram("party2.lat").count == 2
    assert coord.histogram("party2.lat").total == 2.0


def test_histogram_merge_accounts_for_unsampled_overflow():
    src = Registry()
    h = src.histogram("x", max_samples=4)
    for v in range(10):
        h.observe(float(v))
    dst = Registry()
    dst.merge(src.snapshot())
    got = dst.histogram("x")
    assert got.count == 10               # overflow beyond the 4 kept samples
    assert got.total == sum(range(10))


# ------------------------------------------------------------------ breaker
def test_breaker_ordered_transitions_with_injected_clock():
    clock = [0.0]
    seen = []
    b = CircuitBreaker(2, cooldown_s=5.0, clock=lambda: clock[0],
                       on_transition=lambda p, old, new: seen.append(
                           (p, old, new)))
    b.record_failure(7)
    b.allow(7)                           # one failure: still closed
    b.record_failure(7)
    with pytest.raises(CircuitOpenError):
        b.allow(7)                       # threshold hit, cooldown not up
    clock[0] = 5.0
    b.allow(7)                           # cooldown elapsed: probe allowed
    assert b.state(7) == "half_open"
    b.record_success(7)
    assert b.state(7) == "closed"
    assert seen == [(7, "closed", "open"), (7, "open", "half_open"),
                    (7, "half_open", "closed")]
    assert b.transitions == seen


def test_breaker_failed_probe_reopens_immediately():
    clock = [0.0]
    b = CircuitBreaker(3, cooldown_s=1.0, clock=lambda: clock[0])
    for _ in range(3):
        b.record_failure(0)
    clock[0] = 2.0
    b.allow(0)
    assert b.state(0) == "half_open"
    b.record_failure(0)                  # failed probe: no threshold grace
    assert b.state(0) == "open"
    with pytest.raises(CircuitOpenError):
        clock[0] = 2.5                   # cooldown restarts from the reopen
        b.allow(0)


def test_breaker_default_cooldown_none_keeps_legacy_semantics():
    b = CircuitBreaker(1)
    b.record_failure(4)
    with pytest.raises(CircuitOpenError):
        b.allow(4)                       # stays open forever...
    b.record_success(4)
    b.allow(4)                           # ...until an explicit success


def test_breaker_half_open_cycle_under_deterministic_chaos():
    """The satellite regression: a real coordinator round trips the breaker
    closed->open via a chaos-dropped round, the cooled-down probe half-opens
    it, and the recovered round closes it — recorded in order."""
    seen = []
    policy = RetryPolicy(attempts=1, base=0.01, seed=0,
                         sleeper=lambda d: None)
    sub = DistributedSubstrate(2, round_timeout=2.0, retry=policy)
    try:
        sub.coordinator.breaker = CircuitBreaker(
            1, cooldown_s=0.0,
            on_transition=lambda p, old, new: seen.append((p, old, new)))
        prog = sub.program(None, 1, 1,
                           distributed=distributed.toy_affine_spec())
        x = np.arange(8, dtype=np.int32).reshape(2, 4)
        want = np.asarray(prog(x, np.int32(3)))   # healthy round first
        sub.chaos(0, "drop_run")
        with pytest.raises(PartyTimeout):
            prog(x, np.int32(3))                  # budget 1: opens party 0
        got = np.asarray(prog(x, np.int32(3)))    # probe recovers exactly
        np.testing.assert_array_equal(got, want)
        flips = [(old, new) for p, old, new in seen if p == 0]
        assert flips == [("closed", "open"), ("open", "half_open"),
                         ("half_open", "closed")]
    finally:
        sub.shutdown()


# ------------------------------------------------------------ export/report
def _demo_spans(tracer):
    with tracer.span("fit", category="host"):
        with tracer.span("run.forest_fit", category="host", rid=1):
            with tracer.span("coll.sum", category="comm", seq=0):
                pass
            with tracer.span("fit.level", category="compute", level=0):
                pass
    return tracer.spans()


def test_jsonl_roundtrip_and_chrome_trace_shape(tracer, tmp_path):
    spans = _demo_spans(tracer)
    path = tmp_path / "spans.jsonl"
    export_jsonl(spans, str(path))
    assert read_jsonl(str(path)) == spans
    doc = chrome_trace(spans)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert len(events) == len(spans)
    assert any(m["name"] == "process_name" for m in meta)
    assert all(isinstance(e["pid"], int) and e["ts"] >= 0 for e in events)
    json.dumps(doc)                      # must be a serializable artifact


def test_critical_path_and_report_render(tracer):
    spans = _demo_spans(tracer)
    summary = critical_path(spans)
    assert summary["n_spans"] == 4 and summary["n_traces"] == 1
    assert set(summary["by_category_s"]) == {"host", "comm", "compute"}
    assert 0 in summary["levels"]
    text = format_report(spans)
    for needle in ("comm", "compute", "per-level", "slowest"):
        assert needle in text


# --------------------------------------------- end-to-end propagation oracle
@pytest.fixture(scope="function")
def traced_fed(armed_tracer):
    fed = Federation(parties=M, substrate="distributed", n_bins=8,
                     round_timeout=60.0,
                     retry=RetryPolicy(attempts=2, base=0.05, seed=0))
    yield fed
    fed.close()


def test_distributed_fit_yields_one_connected_trace(traced_fed, tmp_path):
    x, y = make_classification(120, 6, 2, seed=0)
    p = ForestParams(n_estimators=2, max_depth=3, n_bins=8, seed=0)
    traced_fed.ingest(x, y)
    traced_fed.fit(p)
    info = traced_fed.collect_telemetry()
    assert set(info) == set(range(M))
    assert sum(v["spans"] for v in info.values()) > 0
    spans = TRACER.spans()
    fit_roots = [s for s in spans
                 if s["parent"] is None and s["name"].startswith("fit.")]
    assert len(fit_roots) == 1
    tid = fit_roots[0]["tid"]
    # ONE connected trace: the fit's coordinator rounds and every party's
    # worker op execution share the trace id, and each span in it hangs
    # off another span of the same trace (worker roots parent under a
    # coordinator-minted sid propagated on the wire)
    trace = [s for s in spans if s["tid"] == tid]
    worker = [s for s in trace if s["proc"].startswith("party")]
    assert {s["proc"] for s in worker} == {f"party{i}" for i in range(M)}
    trace_sids = {s["sid"] for s in trace}
    coord_sids = {s["sid"] for s in trace
                  if not s["proc"].startswith("party")}
    for s in worker:
        assert s["parent"] is not None and s["parent"] in trace_sids
    ops = [s for s in worker if s["name"] == "worker.forest_fit"]
    assert len(ops) == M and all(s["parent"] in coord_sids for s in ops)
    assert any(s["name"].startswith("coll.") for s in worker)
    assert any(s["name"] == "round" for s in trace)
    # exported artifact round-trips with every cross-process span intact
    out = tmp_path / "spans.jsonl"
    n = traced_fed.export_trace(str(out), str(tmp_path / "trace.json"))
    assert n == len(read_jsonl(str(out))) >= len(spans)


def test_retry_backoff_spans_carry_reproducible_schedule(armed_tracer):
    policy = RetryPolicy(attempts=3, base=0.01, seed=7,
                         sleeper=lambda d: None)
    sub = DistributedSubstrate(2, round_timeout=2.0, retry=policy)
    try:
        prog = sub.program(None, 1, 1,
                           distributed=distributed.toy_affine_spec())
        x = np.arange(8, dtype=np.int32).reshape(2, 4)
        prog(x, np.int32(3))
        sub.chaos(0, "drop_run")
        prog(x, np.int32(3))
    finally:
        sub.shutdown()
    backoffs = [s for s in TRACER.spans() if s["name"] == "retry.backoff"]
    want = RetryPolicy(attempts=3, base=0.01, seed=7).delay(0)
    assert [s["attrs"]["delay_s"] for s in backoffs] == [want]
    assert policy.slept == [want]
    assert backoffs[0]["attrs"]["attempt"] == 0


def test_tracing_enabled_is_bit_identical_to_disabled(traced_fed):
    """The zero-cost contract, output half: the traced distributed fit and
    served predictions equal the untraced vmap simulation exactly."""
    x, y = make_classification(120, 6, 2, seed=3)
    p = ForestParams(n_estimators=3, max_depth=3, n_bins=8,
                     max_features=0.5, seed=0)
    sim = Federation(parties=M, n_bins=8)     # untraced in-process reference
    sim.ingest(x, y)
    ref = sim.fit(p)
    traced_fed.ingest(x, y)
    model = traced_fed.fit(p)
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(ref.trees_),
                      jax.tree_util.tree_leaves(model.trees_)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    server = traced_fed.serve(model, ServeConfig(buckets=(64,)))
    np.testing.assert_array_equal(server.serve(x[:40]),
                                  np.asarray(sim.predict(ref, x[:40])))
    assert REGISTRY.counter("serving.waves").value > 0


def test_disabled_path_sends_bit_identical_wire_bytes():
    """The zero-cost contract, wire half: with tracing off, Channel.send
    frames exactly ``pack(msg)`` — no ``_trace`` key, no extra bytes.  With
    a live span, the context key rides the same frame and the payload is
    otherwise untouched."""
    import socket

    from repro.federation import transport

    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    a = socket.create_connection(srv.getsockname())
    b, _ = srv.accept()
    srv.close()
    try:
        cha, chb = transport.Channel(a), transport.Channel(b)
        msg = {"op": "run", "name": "x", "rid": 1}
        assert TRACER.current_context() is None
        cha.send(msg)
        raw = chb._read(4, None)
        (n,) = transport._LEN.unpack(raw)
        frame = chb._read(n, None)
        assert raw + frame == transport.pack(msg)   # byte-identical
        assert "_trace" not in transport.unpack(frame)

        TRACER.enable()
        try:
            with TRACER.span("round", category="comm"):
                ctx = TRACER.current_context()
                cha.send(msg)
            got = chb.recv(timeout=5.0)
        finally:
            TRACER.disable()
            TRACER.reset()
        assert got.pop("_trace") == ctx
        assert got == msg
    finally:
        a.close()
        b.close()
