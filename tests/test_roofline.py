"""Validate the trip-count-aware HLO analyzer against hand-computable cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hlo_analysis, roofline


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = hlo_analysis.analyze_hlo(c.as_text())
    assert t.flops == pytest.approx(2 * n**3, rel=1e-6)


def test_scan_multiplies_trip_count():
    n, trips = 128, 12
    def fn(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=trips)
        return y
    c = _compile(fn, jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = hlo_analysis.analyze_hlo(c.as_text())
    assert t.flops == pytest.approx(trips * 2 * n**3, rel=0.05)
    # and XLA's own number is the known-broken 1x body count
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] == pytest.approx(2 * n**3, rel=1e-6)


def test_nested_scan():
    n, outer, inner = 64, 3, 5
    def fn(x):
        def inner_fn(c, _):
            return c @ c, None
        def outer_fn(c, _):
            y, _ = jax.lax.scan(inner_fn, c, None, length=inner)
            return y, None
        y, _ = jax.lax.scan(outer_fn, x, None, length=outer)
        return y
    c = _compile(fn, jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = hlo_analysis.analyze_hlo(c.as_text())
    assert t.flops == pytest.approx(outer * inner * 2 * n**3, rel=0.05)


def test_collective_bytes_sharded_matmul():
    """Contracting-dim sharded matmul needs an all-reduce of the f32 result.
    Runs in a subprocess with a forced host device count (this process holds
    the single real CPU device)."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import hlo_analysis
mesh = jax.make_mesh((4,), ("model",))
n = 128
a = jax.ShapeDtypeStruct((n, n), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))
b = jax.ShapeDtypeStruct((n, n), jnp.float32,
                         sharding=NamedSharding(mesh, P("model", None)))
c = jax.jit(lambda x, y: x @ y,
            out_shardings=NamedSharding(mesh, P())).lower(a, b).compile()
t = hlo_analysis.analyze_hlo(c.as_text())
expected = n * n * 4
assert expected <= t.coll_bytes <= 3 * expected, t.coll_bytes
print("COLL_OK", t.coll_bytes)
"""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "COLL_OK" in res.stdout


def test_bytes_dominated_by_io():
    n = 512
    c = _compile(lambda a: a + 1.0, jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = hlo_analysis.analyze_hlo(c.as_text())
    assert t.bytes == pytest.approx(2 * n * n * 4, rel=0.5)


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(flops=197e12, hbm_bytes=819e9 / 2, coll_bytes=0,
                          coll_detail={}, per_device_memory=0)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.bottleneck == "compute"
