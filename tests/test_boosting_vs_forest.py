"""Cross-model integration: boosting vs forest on the same vertical data,
plus the F-LR ordering the paper's Table 1 exhibits."""
import numpy as np

from repro.core import ForestParams, FederatedForest
from repro.core.boosting import BoostParams, FederatedBoosting
from repro.core.fedlinear import FederatedLinear, split_columns
from repro.core.party import make_vertical_partition
from repro.data import make_classification
from repro.data.metrics import accuracy


def test_all_three_federated_models_on_shared_partition():
    x, y = make_classification(800, 24, 2, n_informative=8, seed=21)
    xtr, ytr, xte, yte = x[:600], y[:600], x[600:], y[600:]
    part = make_vertical_partition(xtr, 3, 32)

    ff = FederatedForest(ForestParams(n_estimators=10, max_depth=6,
                                      n_bins=32, seed=4)).fit(part, ytr)
    fb = FederatedBoosting(BoostParams(task="binary", n_rounds=20,
                                       max_depth=3)).fit(part, ytr)
    fl = FederatedLinear().fit(split_columns(xtr, 3), ytr)

    accs = {
        "forest": accuracy(yte, ff.predict(xte)),
        "boosting": accuracy(yte, fb.predict(xte)),
        "linear": accuracy(yte, fl.predict(split_columns(xte, 3))),
    }
    for name, a in accs.items():
        assert a > 0.75, (name, a)
    # tree ensembles should at least match the linear baseline on this
    # blob-generated (linearly-separable-ish) data
    assert max(accs["forest"], accs["boosting"]) >= accs["linear"] - 0.05
